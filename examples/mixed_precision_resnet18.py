"""Table VII end-to-end: HAWQ-V3's per-layer INT4/INT8 ResNet18 configs run
through (a) the JAX CNN at those precisions (functional path) and (b) the
BF-IMNA simulator (hardware cost path) — accuracy proxy vs EDP trade-off.

  PYTHONPATH=src python examples/mixed_precision_resnet18.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.apsim.energy import SRAM
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import (HAWQV3_METADATA, HAWQV3_RESNET18,
                                   per_layer_bits, resnet18)
from repro.models import cnn


def main():
    key = jax.random.PRNGKey(0)
    params, layers = cnn.init_cnn("resnet18", key, image=32)
    x = jax.random.normal(key, (4, 32, 32, 3), jnp.float32)

    # fp reference output distribution
    ref = jax.nn.softmax(cnn.cnn_forward(params, x, layers), axis=-1)

    sim_layers = resnet18()
    print(f"{'config':8s} {'avg_b':>6s} {'fidelity':>9s} "
          f"{'EDP(J.s)':>10s} {'norm_E':>7s} {'top1[53]':>8s}")
    base = simulate_network(sim_layers, LR_CONFIG, SRAM, bits=8)
    fwd = jax.jit(lambda p, x, wv, av: cnn.cnn_forward(p, x, layers,
                                                       wv, av),
                  static_argnums=())
    for name in ("int4", "low", "medium", "high", "int8"):
        vec = HAWQV3_RESNET18[name]
        bits = per_layer_bits(sim_layers, vec)
        # functional: run the CNN at these bits; fidelity = agreement with fp
        wv = jnp.asarray(bits, jnp.int32)
        out = jax.nn.softmax(cnn.cnn_forward(params, x, layers, wv, wv),
                             axis=-1)
        fidelity = float(1.0 - 0.5 * jnp.abs(out - ref).sum(-1).mean())
        # hardware: the paper's simulator on the same bit vector
        rep = simulate_network(sim_layers, LR_CONFIG, SRAM, bits=bits,
                               network="resnet18")
        meta = HAWQV3_METADATA[name]
        print(f"{name:8s} {np.mean(bits):6.2f} {fidelity:9.4f} "
              f"{rep.edp:10.3e} {rep.energy_j / base.energy_j:7.3f} "
              f"{meta['top1']:8.2f}")
    print("\nhigher bits -> higher fidelity & higher EDP: the Table VII "
          "trade-off, reproduced functionally AND in hardware cost.")


if __name__ == "__main__":
    main()
