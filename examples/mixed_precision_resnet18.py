"""Table VII end-to-end, through the REAL kernels: HAWQ-V3's per-layer
INT4/INT8 ResNet18 configs run (a) the serve-form CNN — weights quantized
once into int8 containers, every conv-as-GEMM dispatched through
``ops.serve_linear`` with the bit vector as a traced input, all five
configs in ONE compiled program — and (b) the BF-IMNA simulator (hardware
cost path): accuracy proxy vs EDP trade-off, plus a mixed-budget batch
through the CNN serving engine with per-request EDP.

  PYTHONPATH=src python examples/mixed_precision_resnet18.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.apsim.energy import SRAM
from repro.apsim.mapper import LR_CONFIG, simulate_network
from repro.apsim.workloads import (HAWQV3_METADATA, HAWQV3_RESNET18,
                                   per_layer_bits, resnet18)
from repro.core import policy as pol
from repro.models import cnn
from repro.serve.cnn import CNNServeEngine, hawq_fidelity_sweep


def main():
    key = jax.random.PRNGKey(0)
    params, layers = cnn.init_cnn("resnet18", key, image=32)
    x = jax.random.normal(key, (4, 32, 32, 3), jnp.float32)

    # functional: quantize/prepack once, run every HAWQ config through
    # the serve-form kernels in ONE compiled program (fidelity vs fp)
    fid, traces = hawq_fidelity_sweep(image=32, batch=4)

    sim_layers = resnet18()
    print(f"{'config':8s} {'avg_b':>6s} {'fidelity':>9s} "
          f"{'EDP(J.s)':>10s} {'norm_E':>7s} {'top1[53]':>8s}")
    base = simulate_network(sim_layers, LR_CONFIG, SRAM, bits=8)
    for name in ("int4", "low", "medium", "high", "int8"):
        vec = HAWQV3_RESNET18[name]
        # hardware: the paper's simulator on the same bit vector
        rep = simulate_network(sim_layers, LR_CONFIG, SRAM,
                               bits=list(vec), network="resnet18")
        meta = HAWQV3_METADATA[name]
        print(f"{name:8s} {np.mean(per_layer_bits(layers, vec)):6.2f} "
              f"{fid[name]:9.4f} {rep.edp:10.3e} "
              f"{rep.energy_j / base.energy_j:7.3f} {meta['top1']:8.2f}")
    print(f"\nall five configs ran through ONE compiled serve program "
          f"(traces={traces}); higher bits -> higher fidelity & "
          f"higher EDP: the Table VII trade-off through the real kernels.")

    # ---- batched serving: per-image budgets -> per-request EDP ----------
    ctrl = pol.cnn_budget_controller("resnet18", layers=layers)
    eng = CNNServeEngine(params, layers, controller=ctrl, max_batch=4)
    preds = ctrl.predicted_latency_s
    budgets = [preds["hawqv3-int4"] * 1.01, preds["hawqv3-medium"] * 1.01,
               preds["hawqv3-high"] * 1.01, preds["hawqv3-int8"] * 1.01]
    logits, stats = eng.serve(x, budgets)
    print(f"\nmixed-budget batch (EDP budgets, J·s) — "
          f"forward traces: {eng.stats.forward_traces}")
    for s in stats:
        print(f"  img{s.index}: budget={s.budget:.2e} "
              f"mean_wbits={s.mean_wbits:.2f} "
              f"ap_latency={s.ap_latency_s * 1e6:7.1f}us "
              f"ap_energy={s.ap_energy_j * 1e3:6.3f}mJ edp={s.edp:.3e}")

    # ---- closed loop: the SLO picks the precision (DESIGN.md §8) --------
    # no per-image budgets at all — a FluidController charges each image's
    # priced cost against a tight system-level EDP window, so the batch
    # degrades precision image by image to honor it, in the SAME program
    slo = 4 * preds["hawqv3-int8"] * 0.7
    fluid = pol.FluidController.from_open_loop(ctrl, slo=slo, window=4)
    eng2 = CNNServeEngine(params, layers, controller=fluid, max_batch=4)
    _, stats2 = eng2.serve(x)
    print(f"\nclosed loop (EDP SLO {slo:.3e} J·s for the batch, no "
          f"per-image budgets) — forward traces: "
          f"{eng2.stats.forward_traces}")
    for s in stats2:
        print(f"  img{s.index}: headroom={s.budget:.2e} "
              f"mean_wbits={s.mean_wbits:.2f} edp={s.edp:.3e}")
    print(f"spent {sum(s.edp for s in stats2):.3e} of {slo:.3e} J·s")


if __name__ == "__main__":
    main()
