"""Per-request dynamic mixed-precision serving (paper §V.B, at request
granularity): one compiled server, a continuous-batching slot pool, and a
BudgetController that turns each request's latency budget into its own
per-layer bit vector — precision is pure runtime data, so interactive
traffic, background traffic, and everything between share one program.

  PYTHONPATH=src python examples/bitfluid_serving.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve.engine import ServeEngine


def main():
    cfg = configs.get_smoke("stablelm_12b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)

    # three registered configurations, as in Table VII; predicted
    # latencies come from the hardware model (here: bit-proportional)
    ctrl = pol.BudgetController(
        configs={"int4": pol.fixed(4),
                 "mixed": pol.per_layer([8, 4], name="mixed"),
                 "int8": pol.fixed(8)},
        predicted_latency_s={"int4": 0.5, "mixed": 0.75, "int8": 1.0},
        n_layers=n)
    eng = ServeEngine(cfg, qparams, max_len=128, controller=ctrl,
                      n_slots=2, prefill_len=16, decode_block=4)

    # a mixed stream: relaxed analytics traffic, normal chat traffic, and
    # tight-SLO autocomplete traffic, interleaved — more requests than
    # slots, so the scheduler continuously admits into freed slots
    workload = [
        ("analytics (budget 2.0) ", 2.0, 0.0, 0),
        ("chat      (budget 0.8) ", 0.8, 0.8, 8),
        ("complete  (budget 0.4) ", 0.4, 0.0, 0),
        ("chat      (budget 0.8) ", 0.8, 0.8, 8),
        ("complete  (budget 0.4) ", 0.4, 0.0, 0),
    ]
    t0 = time.time()
    rids = {}
    for i, (desc, budget, temp, top_k) in enumerate(workload):
        prompt = np.asarray(make_batch(1, i, 1, 12, cfg.vocab_size)
                            ["tokens"][0])
        rids[eng.submit(prompt, max_new_tokens=6, budget_s=budget,
                        temperature=temp, top_k=top_k)] = desc
    results = eng.run()
    for rid, desc in rids.items():
        st = results[rid]
        print(f"{desc}: served at mean {st.mean_wbits:.1f} weight bits "
              f"on slot {st.slot} -> tokens={st.tokens} "
              f"(AP EDP {st.edp:.2e} J·s)")
    print(f"\n{eng.stats.tokens} tokens, {len(workload)} requests, "
          f"{eng.pool.n_slots} slots, {time.time() - t0:.2f}s wall")
    print(f"compiled once: prefill x{eng.stats.prefill_traces}, "
          f"decode x{eng.stats.decode_traces} — per-request budgets, slot "
          f"churn, and sampling params never touch compiled code (the "
          f"paper's zero-overhead bit fluidity, per request).")


if __name__ == "__main__":
    main()
