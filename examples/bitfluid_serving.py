"""Dynamic mixed-precision serving (paper §V.B): one compiled server,
per-request latency budgets, precision resolved at runtime by the
BudgetController with EDP predictions from the AP simulator.

  PYTHONPATH=src python examples/bitfluid_serving.py
"""
import time

import jax

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve.engine import ServeEngine


def main():
    cfg = configs.get_smoke("stablelm_12b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)

    # three registered configurations, as in Table VII; predicted
    # latencies come from the hardware model (here: bit-proportional)
    ctrl = pol.BudgetController(
        configs={"int4": pol.fixed(4),
                 "mixed": pol.per_layer([8, 4], name="mixed"),
                 "int8": pol.fixed(8)},
        predicted_latency_s={"int4": 0.5, "mixed": 0.75, "int8": 1.0},
        n_layers=n)
    eng = ServeEngine(cfg, qparams, max_len=128, controller=ctrl)

    requests = [
        ("relaxed batch (budget 2.0)", 2.0),
        ("normal batch (budget 0.8)", 0.8),
        ("tight batch (budget 0.4)", 0.4),
    ]
    for desc, budget in requests:
        eng.set_budget(budget)
        batch = {"tokens": make_batch(1, 7, 2, 16, cfg.vocab_size)["tokens"]}
        t0 = time.time()
        out = eng.generate(batch, steps=6)
        wv, _ = eng.controller.resolve(eng.budget_s)
        import numpy as np
        print(f"{desc}: served at mean {float(np.mean(np.asarray(wv))):.1f} "
              f"weight bits ({time.time() - t0:.2f}s wall) "
              f"tokens={out[0].tolist()}")
    print(f"\ncompiled once: prefill x{eng.stats.prefill_traces}, "
          f"decode x{eng.stats.decode_traces} — budget changes never "
          f"touch compiled code (the paper's zero-overhead bit fluidity).")


if __name__ == "__main__":
    main()
