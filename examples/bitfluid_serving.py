"""Per-request dynamic mixed-precision serving (paper §V.B, at request
granularity): one compiled server, a continuous-batching slot pool, and a
BudgetController that turns each request's latency budget into its own
per-layer bit vector — precision is pure runtime data, so interactive
traffic, background traffic, and everything between share one program.

Act two closes the loop (DESIGN.md §8): the same stream under a
system-level EDP SLO with a FluidController — every admission's priced
AP cost is charged against the window, and later requests resolve from
the REMAINING budget, degrading precision live.

  PYTHONPATH=src python examples/bitfluid_serving.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.serve import aggregate, predict_table
from repro.serve.engine import ServeEngine


def main():
    cfg = configs.get_smoke("stablelm_12b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)

    # three registered configurations, as in Table VII; predicted
    # latencies come from the hardware model (here: bit-proportional)
    ctrl = pol.BudgetController(
        configs={"int4": pol.fixed(4),
                 "mixed": pol.per_layer([8, 4], name="mixed"),
                 "int8": pol.fixed(8)},
        predicted_latency_s={"int4": 0.5, "mixed": 0.75, "int8": 1.0},
        n_layers=n)
    eng = ServeEngine(cfg, qparams, max_len=128, controller=ctrl,
                      n_slots=2, prefill_len=16, decode_block=4)

    # a mixed stream: relaxed analytics traffic, normal chat traffic, and
    # tight-SLO autocomplete traffic, interleaved — more requests than
    # slots, so the scheduler continuously admits into freed slots
    workload = [
        ("analytics (budget 2.0) ", 2.0, 0.0, 0),
        ("chat      (budget 0.8) ", 0.8, 0.8, 8),
        ("complete  (budget 0.4) ", 0.4, 0.0, 0),
        ("chat      (budget 0.8) ", 0.8, 0.8, 8),
        ("complete  (budget 0.4) ", 0.4, 0.0, 0),
    ]
    t0 = time.time()
    rids = {}
    for i, (desc, budget, temp, top_k) in enumerate(workload):
        prompt = np.asarray(make_batch(1, i, 1, 12, cfg.vocab_size)
                            ["tokens"][0])
        rids[eng.submit(prompt, max_new_tokens=6, budget_s=budget,
                        temperature=temp, top_k=top_k)] = desc
    results = eng.run()
    for rid, desc in rids.items():
        st = results[rid]
        print(f"{desc}: served at mean {st.mean_wbits:.1f} weight bits "
              f"on slot {st.slot} -> tokens={st.tokens} "
              f"(AP EDP {st.edp:.2e} J·s)")
    print(f"\n{eng.stats.tokens} tokens, {len(workload)} requests, "
          f"{eng.pool.n_slots} slots, {time.time() - t0:.2f}s wall")
    print(f"compiled once: prefill x{eng.stats.prefill_traces}, "
          f"decode x{eng.stats.decode_traces} — per-request budgets, slot "
          f"churn, and sampling params never touch compiled code (the "
          f"paper's zero-overhead bit fluidity, per request).")

    # ---- act two: the same stream, closed-loop, under an EDP SLO --------
    # predictions are deliberately optimistic (half the priced cost): an
    # open loop would trust them and overspend; the FluidController sees
    # every admission's actual charge and adapts the tail of the stream
    preds = predict_table(lm.layer_gemm_dims(cfg), ctrl.configs,
                          axis="edp", units=12 + 6,   # tokens per request
                          head=lm.head_gemm_dims(cfg), optimism=0.5)
    slo = len(workload) * preds["int8"] * 1.2       # tight system budget
    fluid = pol.FluidController(ctrl.configs, preds, n, budget_axis="edp",
                                slo=slo, window=len(workload))
    eng2 = ServeEngine(cfg, qparams, max_len=128, controller=fluid,
                       n_slots=2, prefill_len=16, decode_block=4)
    rids2 = [eng2.submit(np.asarray(make_batch(1, i, 1, 12, cfg.vocab_size)
                                    ["tokens"][0]), max_new_tokens=6)
             for i in range(len(workload))]         # no budgets: SLO drives
    results2 = eng2.run()
    print(f"\nclosed loop (EDP SLO {slo:.2e} J·s over "
          f"{len(workload)} requests):")
    for i, rid in enumerate(rids2):
        st = results2[rid]
        print(f"  req{i}: {st.mean_wbits:.1f} mean wbits, "
              f"EDP {st.edp:.2e} J·s")
    agg = aggregate(results2.values())
    print(f"spent {agg['edp']:.2e} of {slo:.2e} J·s "
          f"({agg['edp'] / slo:.2f}x SLO) — precision degraded mid-stream "
          f"to honor the budget; still compiled once "
          f"(prefill x{eng2.stats.prefill_traces}, "
          f"decode x{eng2.stats.decode_traces}).")


if __name__ == "__main__":
    main()
