"""Quickstart: train a tiny bit-fluid LM, quantize it, serve it at two
runtime precisions — the whole paper pipeline in one minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs
from repro.core import policy as pol
from repro.data.pipeline import make_batch
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.serve.engine import ServeEngine
from repro.train.loop import TrainConfig, make_train_step


def main():
    cfg = configs.get_smoke("qwen3_4b")
    print(f"model: {cfg.name} (smoke) — {cfg.n_layers}L d={cfg.d_model}")

    # ---- 1. mixed-precision training (per-layer bits are runtime data)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2),
                       wbits=(8, 4), abits=(8,))     # layer0=8b, rest 4b
    step_fn, _ = make_train_step(tcfg, cfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, tcfg.optimizer)
    for i in range(20):
        batch = make_batch(0, i, 8, 65, cfg.vocab_size)
        params, opt, m = step_fn(params, opt, batch)
        if i % 5 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.3f}")

    # ---- 2. quantize once, serve at ANY precision (dyadic requant)
    qparams = lm.quantize_params(params, cfg)
    n = lm.n_bit_slots(cfg)
    ctrl = pol.BudgetController(
        {"int4": pol.fixed(4), "int8": pol.fixed(8)},
        {"int4": 1.0, "int8": 2.0}, n)
    eng = ServeEngine(cfg, qparams, max_len=128, controller=ctrl)
    batch = {"tokens": make_batch(0, 99, 2, 17, cfg.vocab_size)["tokens"]}

    eng.set_budget(10.0)      # loose budget -> int8 config
    out8 = eng.generate(batch, steps=8)
    eng.set_budget(0.5)       # tight budget -> int4 config
    out4 = eng.generate(batch, steps=8)
    print(f"  int8 tokens: {out8[0].tolist()}")
    print(f"  int4 tokens: {out4[0].tolist()}")
    print(f"  compiled programs: prefill x{eng.stats.prefill_traces}, "
          f"decode x{eng.stats.decode_traces} "
          f"(precision switched with ZERO recompilation)")


if __name__ == "__main__":
    main()
