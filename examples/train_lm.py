"""End-to-end training driver example: a ~100M-parameter qwen3-family LM
on the synthetic pipeline with checkpointing + straggler watchdog.

Defaults are CPU-friendly (a ~10M model, 60 steps, minutes); pass
``--full`` for the ~100M/300-step configuration on real hardware:

  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (hardware-sized)")
    args, _ = ap.parse_known_args()
    if args.full:
        # ~100M params: 12L x d=768 (qwen3 family), seq 512
        import repro.configs.qwen3_4b as q
        cfgmod = q
        cfgmod.SMOKE = q.FULL.with_(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
            vocab_size=32000, head_dim=64, remat="none")
        sys.argv = [sys.argv[0], "--arch", "qwen3_4b", "--smoke",
                    "--steps", "300", "--batch", "16", "--seq", "512",
                    "--ckpt-dir", "/tmp/repro_ckpt_full",
                    "--wbits", "8", "--abits", "8"]
    else:
        sys.argv = [sys.argv[0], "--arch", "qwen3_4b", "--smoke",
                    "--steps", "60", "--batch", "8", "--seq", "128",
                    "--ckpt-dir", "/tmp/repro_ckpt",
                    "--ckpt-every", "25",
                    "--wbits", "8", "4", "--abits", "8"]
    train_mod.main()


if __name__ == "__main__":
    main()
